"""TAB-Q — Token-wise Adaptive Bit integer Quantization (paper Algorithm 1).

The paper's Algorithm 1, per token:
  1. split sign / magnitude (one bit reserved for sign),
  2. quantize |T| at the maximum level Q̄-1 → reference codes T̂₀,
  3. repeatedly lower Q, re-quantize, and measure the distortion proxy
        δ = mean | round(T̂₀ / 2^(Q̄-Q)) - T̂ |
     stopping at the last Q whose δ ≤ Δ.

Vectorized JAX formulation: the candidate bit levels form a small static set,
so we evaluate δ for every level at once and select, **per token**, the
smallest bit-width whose distortion stays within Δ — exactly the fixed point
of the sequential loop (δ is non-decreasing as Q shrinks for these rounding
ladders; ties resolve identically).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import aiq, aiq_dequant

MIN_BITS = 2


def _rebase_int8(codes: jax.Array, zero: jax.Array, max_bits: int):
    """Shift per-token codes down to start at 0 so they span ≤ 2^(max_bits-1)
    values — an int8 carrier for every max_bits ≤ 8 (int32 otherwise). The
    zero point absorbs the shift: dequant (codes - zero)·scale is unchanged."""
    c_lo = jnp.min(codes, axis=-1, keepdims=True)
    carrier = jnp.int8 if max_bits <= 8 else jnp.int32
    return (codes - c_lo).astype(carrier), zero - c_lo


@dataclasses.dataclass
class TabQResult:
    """Per-token adaptively quantized tensor (a pytree).

    codes : (tokens, D) magnitude codes, rebased per token to [0, Q_max] so
            an int8 carrier fits whenever max_bits ≤ 8 (the wire/payload
            representation — matches kernels.tabq_quantize)
    sign  : (tokens, D) int8 in {-1, 0, +1} — the paper's reserved sign bit
    scale : (tokens, 1) per-token scale
    zero  : (tokens, 1) per-token zero point (absorbs the rebasing shift)
    bits  : (tokens,)  per-token chosen bit-width (includes the sign bit)
    """

    codes: jax.Array
    sign: jax.Array
    scale: jax.Array
    zero: jax.Array
    bits: jax.Array

    def dequantize(self) -> jax.Array:
        return aiq_dequant(self.codes, self.scale, self.zero) * self.sign

    def payload_bits(self) -> jax.Array:
        """Exact payload accounting: D·Q_token bits per token (sign included
        in Q_token per the paper) + 64 bits/token for (scale, zero) + 8 bits
        for the bit-width byte."""
        d = self.codes.shape[-1]
        return jnp.sum(self.bits.astype(jnp.float32) * d).astype(jnp.int32) + self.bits.shape[0] * (64 + 8)


jax.tree_util.register_pytree_node(
    TabQResult,
    lambda r: ((r.codes, r.sign, r.scale, r.zero, r.bits), None),
    lambda _, ch: TabQResult(*ch),
)


@partial(jax.jit, static_argnames=("max_bits",))
def tabq(t: jax.Array, max_bits: int = 8, delta: float = 0.2) -> TabQResult:
    """Algorithm 1, vectorized over tokens.

    ``t``: (tokens, D).  ``max_bits`` = Q̄ (total, incl. sign bit).
    ``delta`` = Δ distortion tolerance.
    """
    sign = jnp.sign(t).astype(jnp.int8)
    mag = jnp.abs(t)
    q_ref = max_bits - 1  # line 4: one bit reserved for the sign
    codes0, s0, z0 = aiq(mag, q_ref, axis=-1)

    n = t.shape[-1]
    levels = list(range(q_ref - 1, MIN_BITS - 1, -1))  # Q̄-1 … MIN_BITS
    if not levels:
        codes0, z0 = _rebase_int8(codes0, z0, max_bits)
        return TabQResult(codes0, sign, s0, z0, jnp.full(t.shape[:-1], max_bits, jnp.int32))

    def level_result(q):
        codes, s, z = aiq(mag, q, axis=-1)
        # line 9: δ = Σ | round(T̂₀ / 2^(Q̄-Q)) - T̂ | / n, per token
        shift = 2.0 ** (q_ref - q)
        delta_q = jnp.sum(jnp.abs(jnp.round(codes0 / shift) - codes), axis=-1) / n
        return codes, s, z, delta_q

    all_codes, all_s, all_z, all_d = [], [], [], []
    for q in levels:
        c, s, z, d = level_result(q)
        all_codes.append(c)
        all_s.append(s)
        all_z.append(z)
        all_d.append(d)
    all_codes = jnp.stack(all_codes)  # (L, tokens, D)
    all_s = jnp.stack(all_s)
    all_z = jnp.stack(all_z)
    all_d = jnp.stack(all_d)  # (L, tokens)

    ok = all_d <= delta  # levels admissible per token
    # sequential semantics: walk down from Q̄-1; stop before the first level
    # whose δ > Δ  →  admissible prefix length per token
    prefix_ok = jnp.cumprod(ok.astype(jnp.int32), axis=0).astype(bool)
    n_ok = jnp.sum(prefix_ok, axis=0)  # 0 .. L
    # n_ok == 0 → keep the initial Q̄-1 quantization
    idx = jnp.maximum(n_ok - 1, 0)  # index into levels
    take_init = n_ok == 0

    def gather(stack, init):
        g = jnp.take_along_axis(
            stack, idx[None, ..., None] if stack.ndim == 3 else idx[None, ...], axis=0
        )[0]
        cond = take_init[..., None] if stack.ndim == 3 else take_init
        return jnp.where(cond, init, g)

    codes = gather(all_codes, codes0)
    scale = gather(all_s[..., 0], s0[..., 0])[..., None]
    zero = gather(all_z[..., 0], z0[..., 0])[..., None]
    bits_mag = jnp.where(take_init, q_ref, jnp.asarray(levels, jnp.int32)[idx])
    bits = bits_mag + 1  # + sign bit
    codes, zero = _rebase_int8(codes, zero, max_bits)
    return TabQResult(codes, sign, scale, zero, bits.astype(jnp.int32))


@partial(jax.jit, static_argnames=("bits",))
def tabq_fixed(t: jax.Array, bits: int) -> TabQResult:
    """Non-adaptive token-wise quantization at a fixed bit-width (used when a
    hard payload budget dictates the level, e.g. Algorithm 2 fallbacks)."""
    sign = jnp.sign(t).astype(jnp.int8)
    codes, s, z = aiq(jnp.abs(t), bits - 1, axis=-1)
    codes, z = _rebase_int8(codes, z, bits)
    return TabQResult(codes, sign, s, z, jnp.full(t.shape[:-1], bits, jnp.int32))
