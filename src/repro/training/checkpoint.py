"""Checkpointing: parameters/optimizer state → sharded ``.npz`` + msgpack
metadata. Restore requires a template pytree (from ``init_params`` /
``adamw_init``) — standard shape-driven restore."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out[key] = leaf
    return out


def save_checkpoint(path: str, tree, step: int = 0):
    os.makedirs(path, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    meta = {"step": step,
            "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                     for k, a in arrays.items()}}
    with open(os.path.join(path, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))


def restore_checkpoint(path: str, template):
    """Restore into the structure of ``template`` (shapes/dtypes verified)."""
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t = _flatten_with_paths(template)
    missing = set(flat_t) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    restored = {}
    for key, tmpl in flat_t.items():
        arr = data[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {tmpl.shape}")
        restored[key] = jnp.asarray(arr, tmpl.dtype)
    # rebuild via tree structure of the template
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    keys_in_order = list(_flatten_with_paths(template))
    return treedef.unflatten([restored[k] for k in keys_in_order]), meta["step"]
