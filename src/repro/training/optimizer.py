"""Hand-rolled AdamW + LR schedules (no optax in this environment — the
optimizer is part of the substrate we own).

State is a pytree mirroring the parameters; all ops are ``tree_map`` based so
the optimizer shards exactly like the parameters under pjit (ZeRO-equivalent
when parameters are FSDP-sharded).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jax.tree_util.tree_map(zeros, params),
                      jax.tree_util.tree_map(zeros, params),
                      jnp.zeros((), jnp.int32))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    lr = lr_schedule(cfg, count)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_params, AdamWState(new_mu, new_nu, count), {"grad_norm": gnorm, "lr": lr}
