"""Training loop: loss, microbatched gradient accumulation, train_step.

``train_step`` is the function the multi-pod dry-run lowers for the
``train_4k`` shape: the global batch is reshaped to (accum, micro, S) and a
``lax.scan`` accumulates gradients — per-device logits stay bounded even at
vocab 256k × 1M tokens (see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import RuntimeOpts, forward_train
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                      adamw_update)


def cross_entropy(logits: jax.Array, labels: jax.Array, loss_mask: jax.Array):
    """Masked next-token CE. Handles the musicgen codebook axis (labels get an
    extra trailing K dim, logits (..., K, V))."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    while nll.ndim > loss_mask.ndim:  # codebook axis → average
        nll = nll.mean(axis=-1)
    denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
    return jnp.sum(nll * loss_mask) / denom


def loss_fn(params, cfg: ArchConfig, batch: dict, opts: RuntimeOpts,
            aux_weight: float = 0.01):
    logits, aux = forward_train(params, cfg, batch["tokens"],
                                batch.get("patches"), opts)
    ce = cross_entropy(logits, batch["labels"], batch["loss_mask"])
    return ce + aux_weight * aux, (ce, aux)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    accum_steps: int = 1  # microbatches per step
    aux_weight: float = 0.01
    batch_pre_split: bool = False  # batch already (accum, micro, ...) shaped


def _split_microbatches(batch: dict, accum: int) -> dict:
    def r(x):
        return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])

    return {k: r(v) for k, v in batch.items() if v is not None}


def make_train_step(cfg: ArchConfig, tc: TrainConfig, opts: RuntimeOpts):
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics). Not jitted here — the caller jits with shardings (launcher) or
    plainly (tests/examples)."""

    def train_step(params, opt_state: AdamWState, batch: dict):
        grad_fn = jax.value_and_grad(
            lambda p, mb: loss_fn(p, cfg, mb, opts, tc.aux_weight), has_aux=True)

        if tc.accum_steps == 1:
            (loss, (ce, aux)), grads = grad_fn(params, batch)
        else:
            micro = (batch if tc.batch_pre_split
                     else _split_microbatches(batch, tc.accum_steps))

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, (ce, aux)), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + jnp.stack([l, ce, aux])), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, sums), _ = jax.lax.scan(body, (g0, jnp.zeros(3)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / tc.accum_steps, grads)
            loss, ce, aux = sums / tc.accum_steps

        new_params, new_state, om = adamw_update(tc.optimizer, grads, opt_state, params)
        metrics = {"loss": loss, "ce": ce, "aux": aux, **om}
        return new_params, new_state, metrics

    return train_step


def init_train_state(cfg: ArchConfig, key, dtype=jnp.float32):
    from repro.models.transformer import init_params

    params = init_params(cfg, key, dtype)
    return params, adamw_init(params)


def train(cfg: ArchConfig, loader, tc: TrainConfig, opts: RuntimeOpts,
          key=None, log_every: int = 20, params=None, opt_state=None):
    """Simple single-host training driver (examples/tests use this; the
    multi-pod launcher in repro.launch wires the same step through pjit)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if params is None:
        params, opt_state = init_train_state(cfg, key)
    step_fn = jax.jit(make_train_step(cfg, tc, opts))
    history = []
    for i, batch in enumerate(loader):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or True:
            history.append({k: float(v) for k, v in metrics.items()})
        if i % log_every == 0:
            print(f"step {i:5d} loss {history[-1]['loss']:.4f} "
                  f"ce {history[-1]['ce']:.4f} lr {history[-1]['lr']:.2e}")
    return params, opt_state, history
